"""Pallas TPU kernel: streaming chunk-prefill attention over the KV pool.

PR 4's chunked prefill was the last dense detour on the paged data plane:
``make_paged_prefill_step`` scattered each chunk's K/V into the page store
and then *gathered every page back out densely* — a ``(B, lanes * ps, KVH,
hd)`` materialization per layer per tick — before attending.  Decode already
streamed pages through ``kernels.paged_attn``; this kernel closes the gap
for the S > 1 prefill path, so prompt chunks read the page store in place
too and the dense per-request KV buffer never exists anywhere.

Layout and grid
---------------
* ``q``: ``(B, S, H, hd)`` — one RIGHT-ALIGNED prompt chunk per row (row
  i's last ``new_lens[i]`` columns are real tokens; the leading columns are
  padding).  Column ``j``'s absolute position is ``cache_len - S + j``.
* ``k_pages``/``v_pages``: ``(n_pages, page_size, KVH, hd)`` — the pool's
  page store, shared by every request.
* grid = ``(B, NQ, P)`` with ``NQ = S / block_q`` query blocks and ``P``
  page lanes: TPU grid steps run sequentially on a core, so the per-(row,
  q-block) softmax state (m/l/acc scratch) accumulates across the ``P``
  inner steps and the output block is emitted at the last page.
* ``page_idx``/``cache_len``/``new_lens`` ride in as **scalar-prefetch**
  operands (``PrefetchScalarGridSpec``): the index map reads
  ``page_idx[b, p]`` to pick which page tile the next grid step DMAs — the
  gather happens in the block-fetch pipeline, never as a materialized
  ``take``.  Unused lanes (``page_idx < 0``) clamp to page 0 and are
  masked out of the softmax.

Masking (all inside the kernel, per (q position, kv position) pair):
* kv position ``t`` is valid iff ``t < cache_len[b]`` and its lane holds a
  real page — the chunk attends to the WHOLE already-paged prefix plus its
  own freshly scattered K/V;
* causality at the right-aligned chunk boundary: ``t <= q_pos``;
* padding query columns (``j < S - new_lens[b]``, or rows past their
  length) are fully masked and emit zeros.

The pure-jnp oracle (:func:`~repro.kernels.ref.paged_chunk_attn_ref`)
mirrors the (row, q-block, page) walk op for op so the CI smoke gate can
require bit equality in interpret mode, not just allclose.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pick_block_q(s: int, limit: int = 32) -> int:
    """Largest divisor of ``s`` that is <= ``limit`` (the VMEM-friendly
    q-block height); always a divisor — 1 at worst, for prime widths."""
    for bq in range(min(s, limit), 0, -1):
        if s % bq == 0:
            return bq
    raise AssertionError(s)          # unreachable: 1 divides everything


def _make_chunk_attn_kernel(quantized: bool):
    """Kernel factory.  ``quantized``: the page blocks are int8 and each is
    followed by its (1, KVH) float32 per-page scale block (fetched through
    the SAME page-index map); dequantization is one cast + broadcast
    multiply at DMA time, inside VMEM — no fp32 copy of any page ever
    exists outside the kernel."""

    def kernel(pi_ref, cl_ref, nl_ref, q_ref, *refs):
        if quantized:
            k_ref, v_ref, ks_ref, vs_ref = refs[:4]
        else:
            k_ref, v_ref = refs[:2]
        o_ref, m_ref, l_ref, acc_ref = refs[-4:]
        b = pl.program_id(0)
        qi = pl.program_id(1)
        p = pl.program_id(2)
        n_p = pl.num_programs(2)

        @pl.when(p == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        ps, kvh, hd = k_ref.shape[1], k_ref.shape[2], k_ref.shape[3]
        bq, h = q_ref.shape[1], q_ref.shape[2]
        n_q = pl.num_programs(1)
        s_total = bq * n_q
        g = h // kvh
        scale = 1.0 / math.sqrt(hd)

        page = pi_ref[b, p]
        clen = cl_ref[b]
        nl = nl_ref[b]
        # absolute positions: queries are the chunk's right-aligned columns,
        # keys are this page's slots; invalid lanes / padding columns masked
        col = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
        q_pos = clen - s_total + col                       # (bq, 1)
        valid_q = (col >= s_total - nl) & (q_pos >= 0)
        t_pos = p * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        valid = (t_pos < clen) & (page >= 0) & (t_pos <= q_pos) & valid_q

        q = q_ref[0].astype(jnp.float32)                   # (bq, H, hd)
        if quantized:
            k = k_ref[0].astype(jnp.float32) * ks_ref[0][None, :, None]
            v = v_ref[0].astype(jnp.float32) * vs_ref[0][None, :, None]
        else:
            k = k_ref[0].astype(jnp.float32)               # (ps, KVH, hd)
            v = v_ref[0].astype(jnp.float32)
        qh = q.reshape(bq, kvh, g, hd)                     # heads grouped by
        s = jnp.einsum("qkgd,skd->qkgs", qh, k,            # their kv head
                       preferred_element_type=jnp.float32) * scale
        s = s.reshape(bq, h, ps)
        s = jnp.where(valid[:, None, :], s, -jnp.inf)

        m_prev = m_ref[...]                                # (bq, H)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        pexp = jnp.where(valid[:, None, :],
                         jnp.exp(s - m_safe[:, :, None]), 0.0)  # (bq, H, ps)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pexp, axis=2)
        pv = jnp.einsum("qkgs,skd->qkgd", pexp.reshape(bq, kvh, g, ps), v,
                        preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, :, None] \
            + pv.reshape(bq, h, hd)
        m_ref[...] = m_new

        @pl.when(p == n_p - 1)
        def _emit():
            l = jnp.maximum(l_ref[...], 1e-20)             # fully-masked rows
            o_ref[0] = (acc_ref[...] / l[:, :, None]).astype(o_ref.dtype)
            #                                                (padding) emit 0
    return kernel


def _chunk_attn_common(q, kv_operands, page_idx, cache_len, new_lens,
                       interpret, block_q):
    """Shared call-path for the fp32 and quantized kernels.
    ``kv_operands`` is (k_pages, v_pages[, k_scale, v_scale])."""
    b, s, h, hd = q.shape
    _, ps, kvh, _ = kv_operands[0].shape
    n_p = page_idx.shape[1]
    assert h % kvh == 0, (h, kvh)
    bq = block_q or _pick_block_q(s)
    assert s % bq == 0, (s, bq)
    n_q = s // bq
    quantized = len(kv_operands) == 4

    def kv_map(bi, qi, p, idx_ref, cl_ref, nl_ref):
        return (jnp.maximum(idx_ref[bi, p], 0), 0, 0, 0)

    def scale_map(bi, qi, p, idx_ref, cl_ref, nl_ref):
        return (jnp.maximum(idx_ref[bi, p], 0), 0)

    def q_map(bi, qi, p, idx_ref, cl_ref, nl_ref):
        return (bi, qi, 0, 0)

    in_specs = [pl.BlockSpec((1, bq, h, hd), q_map),
                pl.BlockSpec((1, ps, kvh, hd), kv_map),
                pl.BlockSpec((1, ps, kvh, hd), kv_map)]
    if quantized:
        in_specs += [pl.BlockSpec((1, kvh), scale_map),
                     pl.BlockSpec((1, kvh), scale_map)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,            # page_idx, cache_len, new_lens
        grid=(b, n_q, n_p),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, h, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((bq, h), jnp.float32),      # running max
            pltpu.VMEM((bq, h), jnp.float32),      # running denominator
            pltpu.VMEM((bq, h, hd), jnp.float32),  # output accumulator
        ],
    )
    return pl.pallas_call(
        _make_chunk_attn_kernel(quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, s, h, hd), q.dtype),
        interpret=interpret,
    )(page_idx.astype(jnp.int32), cache_len.astype(jnp.int32),
      new_lens.astype(jnp.int32), q, *kv_operands)


@functools.partial(jax.jit, static_argnames=("interpret", "block_q"))
def _chunk_attn_call(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     page_idx: jax.Array, cache_len: jax.Array,
                     new_lens: jax.Array, interpret: bool = False,
                     block_q: int = 0) -> jax.Array:
    """q: (B, S, H, hd) right-aligned chunks; k/v_pages: (n_pages, ps, KVH,
    hd); page_idx: (B, P) int32 (-1 = unused lane); cache_len: (B,) total
    valid length AFTER the chunk; new_lens: (B,) valid trailing columns.
    -> (B, S, H, hd) (padding columns zero)."""
    return _chunk_attn_common(q, (k_pages, v_pages), page_idx, cache_len,
                              new_lens, interpret, block_q)


@functools.partial(jax.jit, static_argnames=("interpret", "block_q"))
def _chunk_attn_quant_call(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, k_scale: jax.Array,
                           v_scale: jax.Array, page_idx: jax.Array,
                           cache_len: jax.Array, new_lens: jax.Array,
                           interpret: bool = False,
                           block_q: int = 0) -> jax.Array:
    """Quantized-pool variant: k/v_pages are (n_pages, ps, KVH, hd) int8
    and k/v_scale (n_pages, KVH) float32 per-page scales; both ride the
    same scalar-prefetched page-index path and pages dequantize in VMEM
    (``kernels.quant``).  Same shapes/masking otherwise."""
    return _chunk_attn_common(q, (k_pages, v_pages, k_scale, v_scale),
                              page_idx, cache_len, new_lens, interpret,
                              block_q)
