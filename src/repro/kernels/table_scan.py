"""Pallas TPU kernel: visible-readers-table revocation scan.

The BRAVO writer's revocation step scans the whole visible-readers table for
slots publishing its lock (paper Listing 1 lines 42-44).  The paper's future
work proposes accelerating this scan with SIMD (AVX) and non-polluting
loads; on TPU the idiomatic equivalent is a VPU-vectorized scan that streams
the table through VMEM tiles (never resident in caches the MXU path cares
about).

Layout: the table is shaped (rows, 128) int32 — 128 lanes per VPU register
row; block = (BLOCK_ROWS, 128) tiles.  Outputs: a per-slot match mask (int8)
and the total match count (accumulated across sequential grid steps, as TPU
grid iterations execute in order on a core).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 8


def _scan_kernel(lock_ref, table_ref, mask_ref, count_ref):
    blk = table_ref[...]                       # (BLOCK_ROWS, 128) int32
    m = (blk == lock_ref[0, 0])
    mask_ref[...] = m.astype(jnp.int8)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        count_ref[0, 0] = 0

    count_ref[0, 0] += jnp.sum(m.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _scan_call(table2d: jax.Array, lock_id: jax.Array,
               interpret: bool = False):
    rows, lanes = table2d.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, table2d.shape
    grid = (rows // BLOCK_ROWS,)
    lock = jnp.reshape(lock_id.astype(table2d.dtype), (1, 1))
    mask, count = pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.int8),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(lock, table2d)
    return mask, count[0, 0]


def _poll_kernel(lock_ref, table_ref, count_ref):
    """Early-exit variant: a drain-polling writer only needs zero/nonzero.

    TPU grid steps run sequentially on a core, so once an earlier block has
    found a match every later step skips its compare entirely — the common
    "table still held" poll touches only a prefix of the table.  The count
    returned is exact when zero and a lower bound (>= 1) otherwise.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        count_ref[0, 0] = 0

    @pl.when(count_ref[0, 0] == 0)
    def _scan():
        blk = table_ref[...]
        count_ref[0, 0] = jnp.sum((blk == lock_ref[0, 0]).astype(jnp.int32))


def _multi_poll_kernel(locks_ref, table_ref, counts_ref):
    """Per-lock hold counts for a *vector* of lock values, one table pass.

    The registry drains several locks at once (e.g. freeing a striped KV
    pool) and must poll each lock without disturbing any other lock's bias:
    polling never touches rbias at all, and one streamed pass produces all
    K counts instead of K scans.  The (rows*LANES, K) compare keeps every
    intermediate rank-2 for the VPU.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    blk = table_ref[...]                       # (BLOCK_ROWS, 128)
    flat = blk.reshape(-1, 1)                  # (BLOCK_ROWS*128, 1)
    m = (flat == locks_ref[0, :][None, :])     # (BLOCK_ROWS*128, K)
    counts_ref[0, :] += jnp.sum(m.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _multi_poll_call(table2d: jax.Array, lock_ids: jax.Array,
                     interpret: bool = False) -> jax.Array:
    """-> (K,) int32 exact hold counts, one count per entry of ``lock_ids``."""
    rows, lanes = table2d.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, table2d.shape
    k = lock_ids.shape[0]
    grid = (rows // BLOCK_ROWS,)
    locks = jnp.reshape(lock_ids.astype(table2d.dtype), (1, k))
    counts = pl.pallas_call(
        _multi_poll_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.int32),
        interpret=interpret,
    )(locks, table2d)
    return counts[0, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _poll_call(table2d: jax.Array, lock_id: jax.Array,
               interpret: bool = False) -> jax.Array:
    rows, lanes = table2d.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, table2d.shape
    grid = (rows // BLOCK_ROWS,)
    lock = jnp.reshape(lock_id.astype(table2d.dtype), (1, 1))
    count = pl.pallas_call(
        _poll_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(lock, table2d)
    return count[0, 0]
