"""Public jit'd wrappers for the table kernels.

On CPU hosts the kernels run in ``interpret=True`` mode (the Pallas body
executes in Python — the validation path mandated for this container); on
TPU they compile to Mosaic.
"""

from __future__ import annotations

import functools
import json
import pathlib

import jax
import jax.numpy as jnp

from .paged_attn import _paged_attn_call, _paged_attn_quant_call
from .paged_chunk_attn import _chunk_attn_call, _chunk_attn_quant_call
from .table_publish import (_fused_publish_call, _fused_publish_multi_call,
                            _publish_call)
from .table_scan import LANES, _multi_poll_call, _poll_call, _scan_call

__all__ = ["as_table2d", "revocation_scan", "revocation_poll",
           "revocation_poll_multi", "publish", "clear", "fused_publish",
           "fused_publish_multi", "fused_clear", "paged_attention",
           "paged_attention_quant", "paged_chunk_attention",
           "paged_chunk_attention_quant", "jit_donating", "LANES"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------
# Autotune table: ``kernels/autotune.py`` sweeps the paged kernels' knobs
# (pages-per-DMA-lane for decode, q-block height for chunk prefill) per
# backend and persists the winners next to this module; the wrappers below
# read them here.  Missing file / backend / knob falls back to the default
# — an untuned backend is never an error.
# --------------------------------------------------------------------------

_TUNING_PATH = pathlib.Path(__file__).with_name("tuning_table.json")


@functools.lru_cache(maxsize=None)
def _tuning() -> dict:
    try:
        return json.loads(_TUNING_PATH.read_text())
    except (OSError, ValueError):
        return {}


@functools.lru_cache(maxsize=None)
def _tuned(kernel: str, knob: str, default: int) -> int:
    entry = _tuning().get(kernel, {}).get(jax.default_backend(), {})
    v = entry.get(knob, default)
    return v if isinstance(v, int) and v > 0 else default


def jit_donating(fn, n_donated: int, **jit_kw):
    """``jax.jit`` donating the first ``n_donated`` args — except on CPU
    (the validation backend), which ignores donation and would warn on
    every compile.  One policy for every lease/registry/pool program."""
    donating = jax.default_backend() != "cpu"
    return jax.jit(fn, donate_argnums=tuple(range(n_donated))
                   if donating else (), **jit_kw)


def as_table2d(table_flat: jax.Array) -> jax.Array:
    n = table_flat.shape[0]
    assert n % LANES == 0, n
    return table_flat.reshape(n // LANES, LANES)


def revocation_scan(table2d: jax.Array, lock_id) -> tuple[jax.Array,
                                                          jax.Array]:
    """VPU scan for a revoking writer: -> (match mask int8, match count)."""
    return _scan_call(table2d, jnp.asarray(lock_id, table2d.dtype),
                      interpret=_interpret())


def publish(table2d: jax.Array, slots: jax.Array, ids: jax.Array):
    """Batched CAS(0 -> id): -> (new table, granted bool (M,))."""
    return _publish_call(table2d, slots, ids, interpret=_interpret(),
                         unconditional=False)


def clear(table2d: jax.Array, slots: jax.Array) -> jax.Array:
    """Release: store 0 into each slot."""
    zeros = jnp.zeros_like(slots)
    out, _ = _publish_call(table2d, slots, zeros, interpret=_interpret(),
                           unconditional=True)
    return out


# --------------------------------------------------------------------------
# Fused/aliased fast path (device-BRAVO): the table buffer is donated into
# the kernel (``input_output_aliases``) — no per-call 16KB copy — and the
# rbias recheck + conditional undo happen in kernel, so callers never sync.
# --------------------------------------------------------------------------


def fused_publish(table2d: jax.Array, rbias: jax.Array, slots: jax.Array,
                  ids: jax.Array):
    """Vectorized batched CAS(0 -> id), masked by ``rbias != 0`` in kernel.

    -> (new table [in place], granted bool (M,)).  The input table buffer is
    consumed (aliased); callers must use the returned array."""
    return _fused_publish_call(table2d, rbias, slots, ids,
                               interpret=_interpret(), unconditional=False,
                               check_rbias=True)


def fused_clear(table2d: jax.Array, slots: jax.Array) -> jax.Array:
    """Release: store 0 into each slot, in place (aliased, unconditional)."""
    zeros = jnp.zeros_like(slots, jnp.int32)
    out, _ = _fused_publish_call(table2d, jnp.ones((), jnp.int32), slots,
                                 zeros, interpret=_interpret(),
                                 unconditional=True, check_rbias=False)
    return out


def fused_publish_multi(table2d: jax.Array, rbias_vec: jax.Array,
                        slots: jax.Array, lock_idx: jax.Array,
                        ids: jax.Array):
    """Multi-lock batched CAS(0 -> id): each request is rechecked against
    its OWN lock's bias, gathered from the registry's per-lock ``rbias_vec``
    inside the kernel (no host rbias read, no cross-lock undo).

    -> (new table [in place], granted bool (M,)).  The input table buffer is
    consumed (aliased); callers must use the returned array."""
    return _fused_publish_multi_call(table2d, rbias_vec, slots, lock_idx,
                                     ids, interpret=_interpret())


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_idx: jax.Array, cache_len: jax.Array) -> jax.Array:
    """Gather-by-page decode attention over the KV pool's page store.

    q: (B, H, hd); k/v_pages: (n_pages, page_size, KVH, hd); page_idx:
    (B, P) int32 page-index vectors (-1 = unused lane); cache_len: (B,)
    valid lengths.  -> (B, H, hd).  Each request's pages stream through
    VMEM via scalar-prefetched block indices — the dense (B, S, KVH, hd)
    cache is never materialized."""
    return _paged_attn_call(q, k_pages, v_pages, page_idx, cache_len,
                            interpret=_interpret(),
                            lanes_per_step=_tuned("paged_attn",
                                                  "lanes_per_step", 1))


def paged_attention_quant(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, k_scale: jax.Array,
                          v_scale: jax.Array, page_idx: jax.Array,
                          cache_len: jax.Array) -> jax.Array:
    """Quantized-pool decode attention: same contract as
    :func:`paged_attention` with int8 k/v_pages and (n_pages, KVH) float32
    per-page scales (``kernels.quant`` layout); pages dequantize inside
    the kernel at DMA time — no fp32 page copy is ever materialized."""
    return _paged_attn_quant_call(
        q, k_pages, v_pages, k_scale, v_scale, page_idx, cache_len,
        interpret=_interpret(),
        lanes_per_step=_tuned("paged_attn_quant", "lanes_per_step", 1))


def paged_chunk_attention(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, page_idx: jax.Array,
                          cache_len: jax.Array,
                          new_lens: jax.Array) -> jax.Array:
    """Streaming chunk-prefill attention over the KV pool's page store.

    q: (B, S, H, hd) right-aligned prompt chunks; k/v_pages: (n_pages,
    page_size, KVH, hd); page_idx: (B, P) int32 (-1 = unused lane);
    cache_len: (B,) total valid length AFTER the chunk; new_lens: (B,)
    valid trailing columns per row.  -> (B, S, H, hd), padding columns
    zero.  Pages stream through VMEM via scalar-prefetched block indices —
    the dense (B, lanes * page_size, KVH, hd) gather of the PR-4 prefill
    path is never materialized."""
    s = q.shape[1]
    bq = _tuned("paged_chunk_attn", "block_q", 0)
    return _chunk_attn_call(q, k_pages, v_pages, page_idx, cache_len,
                            new_lens, interpret=_interpret(),
                            block_q=bq if bq and s % bq == 0 else 0)


def paged_chunk_attention_quant(q: jax.Array, k_pages: jax.Array,
                                v_pages: jax.Array, k_scale: jax.Array,
                                v_scale: jax.Array, page_idx: jax.Array,
                                cache_len: jax.Array,
                                new_lens: jax.Array) -> jax.Array:
    """Quantized-pool chunk-prefill attention: same contract as
    :func:`paged_chunk_attention` with int8 k/v_pages and (n_pages, KVH)
    float32 per-page scales; dequantization happens in VMEM."""
    s = q.shape[1]
    bq = _tuned("paged_chunk_attn_quant", "block_q", 0)
    return _chunk_attn_quant_call(
        q, k_pages, v_pages, k_scale, v_scale, page_idx, cache_len,
        new_lens, interpret=_interpret(),
        block_q=bq if bq and s % bq == 0 else 0)


def revocation_poll(table2d: jax.Array, lock_id) -> jax.Array:
    """Early-exit drain poll: 0 iff no slot publishes ``lock_id``; otherwise
    a positive lower bound on the hold count (see ``_poll_kernel``)."""
    return _poll_call(table2d, jnp.asarray(lock_id, table2d.dtype),
                      interpret=_interpret())


def revocation_poll_multi(table2d: jax.Array, lock_ids) -> jax.Array:
    """Exact hold counts for a vector of lock values in ONE table pass —
    the registry's many-locks drain; never touches any lock's bias."""
    return _multi_poll_call(table2d, jnp.asarray(lock_ids, table2d.dtype),
                            interpret=_interpret())
