"""Public jit'd wrappers for the table kernels.

On CPU hosts the kernels run in ``interpret=True`` mode (the Pallas body
executes in Python — the validation path mandated for this container); on
TPU they compile to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .table_publish import _publish_call
from .table_scan import LANES, _scan_call

__all__ = ["as_table2d", "revocation_scan", "publish", "clear", "LANES"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def as_table2d(table_flat: jax.Array) -> jax.Array:
    n = table_flat.shape[0]
    assert n % LANES == 0, n
    return table_flat.reshape(n // LANES, LANES)


def revocation_scan(table2d: jax.Array, lock_id) -> tuple[jax.Array,
                                                          jax.Array]:
    """VPU scan for a revoking writer: -> (match mask int8, match count)."""
    return _scan_call(table2d, jnp.asarray(lock_id, table2d.dtype),
                      interpret=_interpret())


def publish(table2d: jax.Array, slots: jax.Array, ids: jax.Array):
    """Batched CAS(0 -> id): -> (new table, granted bool (M,))."""
    return _publish_call(table2d, slots, ids, interpret=_interpret(),
                         unconditional=False)


def clear(table2d: jax.Array, slots: jax.Array) -> jax.Array:
    """Release: store 0 into each slot."""
    zeros = jnp.zeros_like(slots)
    out, _ = _publish_call(table2d, slots, zeros, interpret=_interpret(),
                           unconditional=True)
    return out
