"""Mesh-rule sharding: one compact per-arch record drives every placement.

This module is the single source of truth for how arrays are laid out on a
device mesh.  Everything else in the tree (configs, models, training,
serving, fault-tolerance, launch dry-run) talks to it through a small,
stable API:

``MeshRules``
    Frozen per-architecture knob record (the configs' hillclimb surface).
    ``MeshRules()`` is always valid: every field has a default, and every
    derived spec degrades to replication when an axis is missing from the
    mesh or a dimension is not divisible by it.

``logical_to_spec(rules, mesh, axes)``
    Map logical axis names to a ``PartitionSpec``.  Logical names:

    * ``"batch"``     -> the tuple of data-parallel axes present in the
      mesh (``rules.batch`` filtered; e.g. ``("pod", "data")`` on the
      multi-pod mesh, ``("data",)`` on a single pod).
    * ``"fsdp"``      -> ``rules.fsdp`` (weight-storage axis, default
      ``"data"``; ``None`` disables FSDP).
    * ``"seq_model"`` -> ``"model"`` when ``rules.residual_seq`` keeps the
      residual stream sequence-sharded, else ``None``.
    * any mesh axis name -> itself; axes absent from the mesh are silently
      dropped (mapped to ``None``), so the same rules run on 1-device CPU
      meshes and 512-chip pods.

``param_specs(pshape, rules, mesh, decode=False)``
    Per-leaf ``PartitionSpec`` tree for a parameter (shape) tree.  Weight
    matrices are tensor-parallel over ``"model"`` on their flattened
    output/input dim (column- and row-parallel respectively) and
    FSDP-sharded over ``rules.fsdp``; MoE expert weights shard experts over
    ``"model"`` and (when ``moe_weight_resident``) ``d_ff`` over the data
    axes; ``decode=True`` drops FSDP (weight-resident serving) and pins the
    expert layout to the decode shard_map contract (E over ``"model"``,
    ``d_ff`` over ``"data"``).

``cache_specs(cshape, rules, mesh, seq_axes=())``
    Specs for decode caches: batch dim over the data axes, the (large)
    KV sequence dim over ``seq_axes``.

``zero1_specs(pspecs, pshape, mesh)``
    ZeRO-1 optimizer-moment specs: params' specs plus a ``"data"`` shard on
    the first free divisible dim when the param spec carries no data axis.

``batch_spec(rules, mesh, shape)`` / ``_divisible(spec, shape, mesh)``
    Input-batch spec helper, and the divisibility guard every public entry
    point funnels through: any spec entry whose mesh-axis product does not
    divide the dimension is replaced by ``None`` (replication) rather than
    erroring.

``constrain(x, rules, mesh, *axes)`` / ``constrain_layer_params(...)``
    ``with_sharding_constraint`` wrappers over logical axes (no-ops when
    ``mesh`` is ``None`` or empty).  ``constrain_layer_params`` re-asserts
    the FSDP storage sharding on per-layer params inside scanned stacks so
    XLA does not keep whole gathered layers live across the scan.

Like the paper's visible-readers table — which diffuses reader state over a
shared array so coherence traffic spreads NUMA-friendly instead of
hammering one counter — the rules here spread the hot state (params,
moments, caches) across mesh axes while keeping the per-arch record itself
a few bytes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "MeshRules", "logical_to_spec", "param_specs", "cache_specs",
    "zero1_specs", "batch_spec", "constrain", "constrain_layer_params",
    "axis_size", "shard_map_compat", "hierarchical_psum",
]


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Per-architecture sharding knobs (see the configs for rationale)."""

    batch: Tuple[str, ...] = ("pod", "data")  # logical "batch" axes, in order
    fsdp: Optional[str] = "data"     # weight-storage shard axis; None = off
    tp_weights: bool = True          # TP-shard weight matrices over "model"
    shard_heads: bool = True         # head-sharded attention activations
    shard_kv_heads: bool = False     # TP-shard wk/wv (GQA K/V is small)
    attn_impl: str = "flash"         # "flash" | "seqshard" (heads % TP != 0)
    residual_seq: bool = False       # residual stream stays (B, S/model, d)
    split_moe_tokens: bool = True    # MoE dispatch splits tokens over model
    moe_weight_resident: bool = True  # expert d_ff sharded over data axes

    def batch_axes(self, mesh: Mesh) -> Tuple[str, ...]:
        """The data-parallel axes actually present in ``mesh``."""
        return tuple(a for a in self.batch if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# Axis resolution + divisibility guard
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def _resolve(rules: MeshRules, mesh: Mesh, name):
    names = mesh.axis_names
    if name is None:
        return None
    if isinstance(name, (tuple, list)):
        got = tuple(a for a in name if a in names)
        return got if got else None
    if name == "batch":
        got = rules.batch_axes(mesh)
        return got if got else None
    if name == "fsdp":
        return rules.fsdp if rules.fsdp in names else None
    if name == "seq_model":
        return "model" if (rules.residual_seq and "model" in names) else None
    return name if name in names else None


def logical_to_spec(rules: MeshRules, mesh: Mesh,
                    axes: Sequence[Any]) -> P:
    """Map logical axis names to a PartitionSpec, dropping missing axes."""
    return P(*[_resolve(rules, mesh, a) for a in axes])


def _divisible(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Replicate (None out) any spec dim the mesh axes don't divide."""
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    out = []
    for dim, s in zip(shape, entries):
        n = _axis_size(mesh, s)
        out.append(s if (s is not None and n > 0 and dim % n == 0) else None)
    return P(*out)


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------


def constrain(x: jax.Array, rules: MeshRules, mesh: Optional[Mesh],
              *axes) -> jax.Array:
    """with_sharding_constraint over logical axes; no-op off-mesh."""
    if mesh is None or getattr(mesh, "empty", False):
        return x
    spec = _divisible(logical_to_spec(rules, mesh, axes), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_layer_params(lp: Any, rules: MeshRules,
                           mesh: Optional[Mesh]) -> Any:
    """Re-assert FSDP/TP storage sharding on one scanned layer's params.

    Inside ``lax.scan`` over a stacked layer dim, XLA is free to keep the
    gathered per-layer weights live; constraining them back to their
    storage specs bounds live memory to one layer's gather."""
    if mesh is None or getattr(mesh, "empty", False):
        return lp
    if not rules.tp_weights and _resolve(rules, mesh, "fsdp") is None:
        return lp
    specs = _spec_tree(lp, rules, mesh, decode=False)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)),
        lp, specs, is_leaf=lambda v: hasattr(v, "shape"))


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

# Per-layer vectors / scalars: always replicated.
_REPLICATED = frozenset({
    "ln", "final_ln", "ln1", "ln2", "ln_x", "out_ln",
    "maa_x", "maa_wkvrg", "decay_base", "cm_mk", "cm_mr",
    "a_log", "dt_bias", "d_skip", "bonus", "router",
})
# Column-parallel (in, out): model on the output dim, fsdp on the input dim.
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv", "wi", "wg", "wr", "lm_head",
    "maa_w1", "decay_w1", "cm_k", "cm_r", "in_proj",
})
# Row-parallel (in, out): model on the input dim, fsdp on the output dim.
_ROW_PARALLEL = frozenset({
    "wo", "cm_v", "out_proj", "maa_w2", "decay_w2",
})


def _core_spec(path: Tuple[str, ...], key: str, ndim: int,
               rules: MeshRules, mesh: Mesh, decode: bool):
    """Trailing-dim spec entries for one leaf; leading stack dims -> None."""
    names = mesh.axis_names
    model = "model" if (rules.tp_weights and "model" in names) else None
    fsdp = None if decode else _resolve(rules, mesh, "fsdp")

    in_moe = "moe" in path and "shared" not in path
    if in_moe and key in ("wi", "wg", "wo"):
        # Expert-parallel weights (E, d_in, d_out): E over "model"; with
        # weight-resident EP the ff dim additionally shards over the data
        # axes (training) / exactly "data" (the decode shard_map contract).
        ep = "model" if "model" in names else None
        if decode:
            wr = "data" if "data" in names else None
        else:
            wr = (rules.batch_axes(mesh) or None) \
                if rules.moe_weight_resident else None
        core = (ep, wr, None) if key == "wo" else (ep, None, wr)
        return (None,) * (ndim - 3) + core

    if key in _REPLICATED:
        return (None,) * ndim
    if key == "embed":
        # (vocab, d): the TP head reads it transposed -> vocab over model
        # (kept even under tp_weights=False: "except the vocab", minicpm).
        m = "model" if "model" in names else None
        return (None,) * (ndim - 2) + (m, fsdp)
    if key == "lora_a":
        return (None,) * (ndim - 2) + (fsdp, None)
    if key == "lora_b":
        return (None,) * (ndim - 2) + (None, model)
    if key in ("wk", "wv") and any(a in ("attn", "shared_attn")
                                   for a in path):
        # GQA/MQA K/V projections are small; TP-shard only when the rules
        # say the kv heads split cleanly.
        m = model if rules.shard_kv_heads else None
        return (None,) * (ndim - 2) + (fsdp, m)
    if key in _COL_PARALLEL:
        return (None,) * (ndim - 2) + (fsdp, model)
    if key in _ROW_PARALLEL:
        return (None,) * (ndim - 2) + (model, fsdp)
    # Unknown leaf: stacked weights (>=3 dims) get the generic column
    # layout on their trailing matmul dims; vectors replicate.
    if ndim >= 3:
        return (None,) * (ndim - 2) + (fsdp, model)
    return (None,) * ndim


def _spec_tree(tree: Any, rules: MeshRules, mesh: Mesh, decode: bool,
               path: Tuple[str, ...] = ()) -> Any:
    if isinstance(tree, dict):
        return {k: _spec_tree(v, rules, mesh, decode, path + (k,))
                for k, v in tree.items()}
    shape = tuple(tree.shape)
    key = path[-1] if path else ""
    core = _core_spec(path, key, len(shape), rules, mesh, decode)
    return _divisible(P(*core), shape, mesh)


def param_specs(pshape: Any, rules: MeshRules, mesh: Mesh,
                decode: bool = False) -> Any:
    """PartitionSpec tree for a parameter (shape) tree.

    ``decode=True`` derives the serving layout: FSDP off (weights resident),
    MoE experts pinned to the decode shard_map contract."""
    return _spec_tree(pshape, rules, mesh, decode)


# ---------------------------------------------------------------------------
# Optimizer-state and cache specs
# ---------------------------------------------------------------------------


def zero1_specs(pspecs: Any, pshape: Any, mesh: Mesh) -> Any:
    """ZeRO-1 moment specs: add a "data" shard where params carry none."""
    if "data" not in mesh.axis_names:
        return pspecs
    nd = mesh.shape["data"]

    def one(spec: P, leaf) -> P:
        shape = tuple(leaf.shape)
        entries = list(tuple(spec)) + [None] * (len(shape) - len(tuple(spec)))
        used = set()
        for e in entries:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a:
                    used.add(a)
        if "data" in used:
            return P(*entries)
        for i, (dim, e) in enumerate(zip(shape, entries)):
            if e is None and dim >= nd and dim % nd == 0:
                entries[i] = "data"
                break
        return P(*entries)

    return jax.tree.map(one, pspecs, pshape,
                        is_leaf=lambda x: isinstance(x, P))


# Decode-cache leaves: core (unstacked) rank, and — for attention KV —
# the sequence dim's position within the core.  Batch is core dim 0.
_CACHE_CORE = {
    "k": (4, 1),        # (B, S, KVH, hd)
    "v": (4, 1),
    "shift1": (2, None),  # (B, d)
    "shift2": (2, None),
    "state": (4, None),   # (B, H, K, V) / (B, nh, ds, hd)
    "conv": (3, None),    # (B, conv-1, d_inner)
}


def cache_specs(cshape: Any, rules: MeshRules, mesh: Mesh,
                seq_axes: Sequence[str] = ()) -> Any:
    """Specs for decode caches: batch over the data axes, the (large) KV
    sequence dim over ``seq_axes`` (e.g. ``("model",)``; ``("data",
    "model")`` for B==1 long-context decode)."""
    bax = rules.batch_axes(mesh) or None
    seq = tuple(a for a in seq_axes if a in mesh.axis_names)

    def one(path: Tuple[str, ...], leaf) -> P:
        shape = tuple(leaf.shape)
        key = path[-1] if path else ""
        core_ndim, seq_at = _CACHE_CORE.get(key, (None, None))
        if core_ndim is None or len(shape) < core_ndim:
            return P(*([None] * len(shape)))
        entries = [None] * len(shape)
        b_at = len(shape) - core_ndim
        entries[b_at] = bax
        if seq_at is not None:
            # never double-book an axis already used for the batch dim
            sq = tuple(a for a in seq if a not in (bax or ()))
            entries[b_at + seq_at] = sq or None
        return _divisible(P(*entries), shape, mesh)

    def walk(node, path=()):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        return one(path, node)

    return walk(cshape)


def batch_spec(rules: MeshRules, mesh: Mesh, shape: Sequence[int]) -> P:
    """Spec for a (B, ...) input leaf: batch axes on dim 0, rest replicated."""
    bax = rules.batch_axes(mesh) or None
    return _divisible(P(bax, *([None] * (len(shape) - 1))), tuple(shape),
                      mesh)


# ---------------------------------------------------------------------------
# shard_map compatibility (jax.shard_map landed after 0.4.x)
# ---------------------------------------------------------------------------


def axis_size(name: str):
    """Size of a mapped mesh axis inside shard_map (jax.lax.axis_size is
    newer than 0.4.x; psum of 1 is the portable spelling)."""
    ax = getattr(jax.lax, "axis_size", None)
    if ax is not None:
        return ax(name)
    return jax.lax.psum(1, name)


def hierarchical_psum(x, axes: Sequence[str]):
    """Topology-aware all-reduce: psum one mesh axis at a time, innermost
    (fastest interconnect) first.

    ``axes`` is ordered outermost-first, matching mesh axis order — e.g.
    ``("pod", "data")`` reduces within each pod over the ICI "data" axis,
    then combines the per-pod partials over the slow DCN "pod" axis.  A
    single psum over ``("pod", "data")`` would let the compiler pick one
    flat all-reduce spanning both fabrics; staging it keeps the cross-pod
    step down to one scalar/partial per pod (the RMA-locks distribution
    pattern).  Inside ``shard_map`` only."""
    for a in reversed(tuple(axes)):
        x = jax.lax.psum(x, a)
    return x


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=False):
    """jax.shard_map when available, else the experimental spelling
    (``check_vma`` was called ``check_rep`` there)."""
    import inspect

    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {"check_vma": check_vma}
        if "check_vma" not in inspect.signature(sm).parameters:
            kw = {"check_rep": check_vma}  # pre-rename signature
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=check_vma)
