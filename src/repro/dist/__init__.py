"""Distributed-execution layer: mesh-rule sharding for params, optimizer
state, KV/SSM caches, and activations.

The design mirrors the paper's visible-readers table (BRAVO, 2018): hot
state is *diffused* across topology axes instead of centralized, while the
per-instance footprint — here a single small :class:`MeshRules` record per
architecture — stays compact (cf. Compact NUMA-aware Locks, Dice & Kogan
2018).
"""

from .sharding import (MeshRules, axis_size, batch_spec, cache_specs,
                       constrain, constrain_layer_params, logical_to_spec,
                       param_specs, shard_map_compat, zero1_specs)

__all__ = [
    "MeshRules", "axis_size", "batch_spec", "cache_specs", "constrain",
    "constrain_layer_params", "logical_to_spec", "param_specs",
    "shard_map_compat", "zero1_specs",
]
