"""MiniCPM-2B [dense] — llama-like, MHA (kv=36), tied embeddings, trained
with the WSD schedule (wired into training/optimizer.py).
[arXiv:2404.06395; hf]"""

from ..dist.sharding import MeshRules
from ..models.common import ModelConfig

import jax.numpy as jnp

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
    d_ff=5760, vocab=122753,
    tie_embeddings=True,
    # pure-SP training keeps weights replicated over model: bf16 master
    # weights so params+grads+ZeRO-1 moments fit (EXPERIMENTS.md §Perf it.6)
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="minicpm-smoke", family="dense",
    n_layers=2, d_model=72, n_heads=6, n_kv_heads=6, head_dim=12,
    d_ff=144, vocab=512, tie_embeddings=True,
)

# 36 heads % 16 != 0: weights shard on flattened q_dim (2304 % 16 == 0),
# head-split activations stay unsharded over model.
# §Perf iteration 6: 36 heads %% 16 != 0 made TP attention reshard every
# block (388GB/chip of residual gathers).  Pure sequence parallelism:
# weights replicated over model (except the 122k vocab), the residual
# stream stays (batch, seq/model, d) end to end -> attention/MLP run with
# ZERO per-layer collectives; only K/V gathers, grad reductions and the
# head remain.
RULES = MeshRules(shard_heads=False, attn_impl="seqshard",
                  tp_weights=False, residual_seq=True, fsdp=None)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
