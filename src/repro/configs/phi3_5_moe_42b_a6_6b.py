"""Phi-3.5-MoE 42B-A6.6B [moe] — 16 experts, top-2 routing, every layer MoE,
GQA kv=8.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""

import jax.numpy as jnp

from ..dist.sharding import MeshRules
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6400, vocab=32064,
    moe_experts=16, moe_top_k=2, moe_every=1, moe_shared_expert=False,
    moe_d_ff=6400,
    # 16 experts fit one-per-chip at bf16 -> fully resident experts
    # (no ff sharding, no per-layer gathers); m/v are ZeRO-1 sharded.
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=512,
    moe_experts=4, moe_top_k=2, moe_every=1, moe_d_ff=96,
)

# §Perf iteration 5: experts fully resident (E over model only — one expert
# per chip; d_ff unsharded), dense weights replicated over data (no FSDP):
# eliminates every per-layer weight/activation gather except the small
# dispatch a2a.  fsdp=None is safe because ZeRO-1 moment sharding carries
# the optimizer memory.
RULES = MeshRules(shard_heads=True, fsdp=None, moe_weight_resident=False)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
