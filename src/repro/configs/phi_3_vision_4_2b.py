"""Phi-3-vision 4.2B [vlm] — phi3-mini text backbone + CLIP frontend (STUB:
input_specs provides precomputed patch embeddings).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""

from ..dist.sharding import MeshRules
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064,
    frontend="vision_stub", frontend_tokens=256,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="phi3v-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, frontend="vision_stub", frontend_tokens=8,
)

RULES = MeshRules(shard_heads=True, shard_kv_heads=True)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
