"""HuBERT X-Large [audio] — encoder-only (bidirectional), conv feature
frontend STUBBED (input_specs provides frame embeddings); masked-prediction
head over 504 clusters.  [arXiv:2106.07447; unverified]"""

from ..dist.sharding import MeshRules
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504,
    causal=False, use_rope=False, glu=False, act="gelu",
    frontend="audio_stub",
)

SMOKE = ModelConfig(
    name="hubert-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=64, causal=False, use_rope=False, glu=False, act="gelu",
    frontend="audio_stub",
)

RULES = MeshRules(shard_heads=True, shard_kv_heads=True)

# encoder-only: no decode step (DESIGN.md §Arch-applicability)
SHAPES = ("train_4k", "prefill_32k")
