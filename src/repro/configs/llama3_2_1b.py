"""Llama-3.2-1B [dense] — small llama3, GQA kv=8, tied embeddings.
[hf:meta-llama/Llama-3.2-1B; unverified]"""

from ..dist.sharding import MeshRules
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=128256,
    tie_embeddings=True, rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama3.2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, tie_embeddings=True,
)

RULES = MeshRules(shard_heads=True)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
