"""Gemma-2B [dense] — GeGLU, head_dim=256, MQA (kv=1), 256k vocab, tied
embeddings.  [arXiv:2403.08295; hf]"""

from ..dist.sharding import MeshRules
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000,
    act="gelu", glu=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=192, vocab=512, act="gelu", glu=True, tie_embeddings=True,
)

# 8 heads < |model|=16: attention activations replicated over model; the
# (huge) 256k-vocab embedding + GeGLU FFN carry the TP sharding instead.
RULES = MeshRules(shard_heads=False, attn_impl="seqshard")

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
