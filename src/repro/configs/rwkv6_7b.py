"""RWKV-6 "Finch" 7B [ssm] — attention-free, data-dependent decay, O(1)
decode state -> runs the 500k long-context decode shape.
[arXiv:2404.05892; hf]"""

from ..dist.sharding import MeshRules
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab=65536,
    use_rope=False,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, head_dim=64,
    d_ff=256, vocab=512, use_rope=False,
)

RULES = MeshRules(shard_heads=True)

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
