"""Assigned architecture configs (one module per arch) + shape table.

Every module defines:
  CONFIG: ModelConfig          — the exact published configuration
  SMOKE:  ModelConfig          — reduced same-family config for CPU tests
  RULES:  MeshRules            — per-arch sharding rules (hillclimb knobs)
  SHAPES: tuple[str, ...]      — applicable input shapes (skips documented
                                 in DESIGN.md §Arch-applicability)
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

from ..dist.sharding import MeshRules
from ..models.common import ModelConfig

ARCH_IDS = (
    "llama4-maverick-400b-a17b",
    "phi3.5-moe-42b-a6.6b",
    "phi-3-vision-4.2b",
    "hubert-xlarge",
    "minicpm-2b",
    "granite-20b",
    "gemma-2b",
    "llama3.2-1b",
    "rwkv6-7b",
    "zamba2-2.7b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str     # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get(arch: str):
    """-> (ModelConfig, MeshRules, applicable shape names)."""
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.CONFIG, getattr(mod, "RULES", MeshRules()), mod.SHAPES


def get_smoke(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.SMOKE


def all_cells():
    """Every (arch, shape) cell, with skip reasons for inapplicable ones."""
    cells = []
    for a in ARCH_IDS:
        _, _, shapes = get(a)
        for s in SHAPES:
            if s in shapes:
                cells.append((a, s, None))
            else:
                reason = ("encoder-only: no decode step" if a == "hubert-xlarge"
                          and "decode" in SHAPES[s].kind or s == "decode_32k"
                          and a == "hubert-xlarge"
                          else "full-attention arch: 500k decode out of "
                               "contract (needs sub-quadratic attention)")
                cells.append((a, s, reason))
    return cells
