"""Granite-20B (code) [dense] — MQA (kv=1), wide FFN (gpt-bigcode style,
non-GLU GELU).  [arXiv:2405.04324; hf]"""

from ..dist.sharding import MeshRules
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab=49152,
    glu=False, act="gelu",
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=256, vocab=512, glu=False, act="gelu",
)

RULES = MeshRules(shard_heads=True)  # 48 % 16 == 0; kv=1 replicated

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
