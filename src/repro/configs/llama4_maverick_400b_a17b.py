"""Llama-4 Maverick 400B-A17B [moe] — interleaved MoE (every other layer),
128 routed experts top-1 + one shared expert, GQA kv=8, early-fusion
multimodal (text path only here).  [hf:meta-llama/Llama-4-*; unverified]"""

import jax.numpy as jnp

from ..dist.sharding import MeshRules
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048,
    moe_experts=128, moe_top_k=1, moe_every=2, moe_shared_expert=True,
    moe_d_ff=8192, rope_theta=500000.0,
    # 400B on 16GB chips: bf16 master weights + bf16 Adam moments
    # (EXPERIMENTS.md §Dry-run memory table documents the fit)
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="llama4-smoke", family="moe",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    moe_experts=8, moe_top_k=1, moe_every=2, moe_shared_expert=True,
    moe_d_ff=128,
)

# 40 heads is not divisible by |model|=16: keep head-dim activations
# unsharded; weights still shard on the flattened q_dim (5120 % 16 == 0).
RULES = MeshRules(shard_heads=False, attn_impl="seqshard")

SHAPES = ("train_4k", "prefill_32k", "decode_32k")  # full attention: no 500k
