"""Zamba2-2.7B [hybrid] — 54 Mamba-2 blocks + a shared attention block
(every 6th position, per-site LoRA), ssm_state=64.  Hybrid -> runs the 500k
long-context decode shape with the attention KV cache sequence-sharded.
[arXiv:2411.15242; hf]"""

from ..dist.sharding import MeshRules
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab=32000,
    ssm_kind="mamba2", ssm_state=64, ssm_expand=2, hybrid_attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512,
    ssm_kind="mamba2", ssm_state=16, ssm_expand=2, hybrid_attn_every=2,
)

RULES = MeshRules(shard_heads=True, shard_kv_heads=True)

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
