from .pipeline import DataConfig, ShardIndex, SyntheticLM, make_batches

__all__ = ["DataConfig", "ShardIndex", "SyntheticLM", "make_batches"]
