"""Deterministic synthetic-LM data pipeline with a BRAVO-guarded shard index.

The token stream is a seeded Zipf-ish mixture (deterministic per (shard,
step) so restarts can replay exactly — the fault-tolerance tests rely on
it).  Multiple loader threads *read* the shard-assignment index for every
batch they cut; the index is *written* only on epoch boundaries or elastic
rescales — a read-dominated pattern guarded by a selectable rwlock, and the
second first-class BRAVO integration point.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..core.factory import LockEnv


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_shards: int = 64
    seed: int = 1234


class SyntheticLM:
    """Deterministic pseudo-corpus: next token depends on previous tokens
    (so a model can actually reduce loss on it)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def sample(self, shard: int, step: int,
               n_seq: int) -> Tuple[np.ndarray, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + shard) * 1_000_033 + step)
        S = cfg.seq_len
        base = rng.integers(0, cfg.vocab, size=(n_seq, S), dtype=np.int64)
        # inject learnable structure: token[t] == f(token[t-1]) 50% of time
        follow = (base[:, :-1] * 31 + 7) % cfg.vocab
        mask = rng.random((n_seq, S - 1)) < 0.5
        base[:, 1:] = np.where(mask, follow, base[:, 1:])
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        return tokens, labels


class ShardIndex:
    """shard -> loader assignment, rwlock-guarded (read-dominated)."""

    def __init__(self, n_shards: int, n_loaders: int, lock):
        self.lock = lock
        self.n_shards = n_shards
        self.assign = np.arange(n_shards) % max(n_loaders, 1)
        self.epoch = 0

    def shards_of(self, loader: int) -> np.ndarray:
        tok = self.lock.acquire_read()
        try:
            return np.where(self.assign == loader)[0].copy()
        finally:
            self.lock.release_read(tok)

    def rebalance(self, n_loaders: int) -> None:
        """Elastic rescale: reassign shards (writer)."""
        tok = self.lock.acquire_write()
        try:
            self.assign = np.arange(self.n_shards) % max(n_loaders, 1)
            self.epoch += 1
        finally:
            self.lock.release_write(tok)


def make_batches(cfg: DataConfig, *, loader: int = 0, n_loaders: int = 1,
                 start_step: int = 0,
                 index: Optional[ShardIndex] = None,
                 env: Optional[LockEnv] = None,
                 lock_name: str = "bravo-ba") -> Iterator[Dict[str, np.ndarray]]:
    """Yields {"tokens","labels"} batches; deterministic in (cfg, step)."""
    gen = SyntheticLM(cfg)
    if index is None:
        env = env or LockEnv()
        index = ShardIndex(cfg.n_shards, n_loaders, env.make(lock_name))
    step = start_step
    per = cfg.global_batch // max(n_loaders, 1)
    while True:
        shards = index.shards_of(loader)
        shard = int(shards[step % len(shards)])
        tokens, labels = gen.sample(shard, step, per)
        yield {"tokens": tokens, "labels": labels}
        step += 1
