"""Fault-tolerance drill: train, kill mid-run, restart from the last
committed checkpoint with a CHANGED worker count (elastic rescale), and
verify the loss trajectory continues; a straggling host is detected and
excluded from the new membership.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro import configs
from repro.data import DataConfig, ShardIndex, make_batches
from repro.core.factory import LockEnv
from repro.dist.sharding import MeshRules
from repro.ft.checkpoint import (CheckpointManager, latest_step,
                                 load_checkpoint)
from repro.ft.elastic import remicrobatch, reshard_tree
from repro.ft.straggler import StragglerDetector
from repro.models import model as M
from repro.training.optimizer import OptimizerConfig, adamw_init
from repro.training.train_step import TrainConfig, make_train_step

CKPT = "/tmp/repro_elastic_ckpt"


def main() -> None:
    import shutil
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = configs.get_smoke("llama3.2-1b")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    rules = MeshRules()
    opt = OptimizerConfig(lr=2e-3, warmup_steps=5)
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=16)
    mgr = CheckpointManager(CKPT, keep=2)
    det = StragglerDetector(hosts=4, slow_factor=2.0)

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    state = adamw_init(params, opt)
    step = jax.jit(make_train_step(cfg, opt, mesh, rules,
                                   TrainConfig(remat="none")))

    # ---- phase 1: 4 "hosts", host 3 is slow; crash at step 25 ----
    it = make_batches(data)
    with mesh:
        for s in range(25):
            b = next(it)
            params, state, m = step(
                params, state, {k: jnp.asarray(v) for k, v in b.items()})
            for h in range(4):
                det.heartbeat(h, 100.0 if h != 3 else 350.0)
            if (s + 1) % 10 == 0:
                mgr.save_async(s + 1, {"params": params, "state": state})
                print(f"[run1] step {s+1} loss {float(m['loss']):.4f} "
                      f"(checkpoint)")
    mgr.wait()
    snap = det.snapshot()
    print(f"[run1] CRASH simulated at step 25. stragglers={snap['stragglers']}")

    # ---- phase 2: restart on 3 hosts (straggler excluded) ----
    last = latest_step(CKPT)
    print(f"[run2] resuming from step {last} on 3 hosts "
          f"(excluded host 3); remicrobatch -> "
          f"{remicrobatch(data.global_batch, 1, 4096, data.seq_len)}")
    restored = load_checkpoint(CKPT, last, {"params": params,
                                            "state": state})
    pshape = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0),
                                                  cfg))
    params = reshard_tree(restored["params"], pshape, rules, mesh)
    state = jax.tree.map(jnp.asarray, restored["state"])
    # elastic data rebalance: 4 loaders -> 3 (writer path of the shard lock)
    env = LockEnv()
    idx = ShardIndex(data.n_shards, 4, env.make("bravo-ba"))
    idx.rebalance(3)
    it = make_batches(data, start_step=last, index=idx)
    with mesh:
        for s in range(last, last + 15):
            b = next(it)
            params, state, m = step(
                params, state, {k: jnp.asarray(v) for k, v in b.items()})
    print(f"[run2] step {s+1} loss {float(m['loss']):.4f} — continued "
          f"cleanly after elastic restart")


if __name__ == "__main__":
    main()
