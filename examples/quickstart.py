"""Quickstart: train a ~100M-param llama3-family model for a few hundred
steps on the deterministic synthetic corpus, with async checkpointing and
restart-on-failure — the end-to-end training driver (deliverable (b)).

    PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.data import DataConfig, make_batches
from repro.dist.sharding import MeshRules
from repro.ft.checkpoint import CheckpointManager, latest_step, \
    load_checkpoint
from repro.models import model as M
from repro.models.common import ModelConfig
from repro.training.optimizer import OptimizerConfig, adamw_init
from repro.training.train_step import TrainConfig, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    # CPU-friendly overrides (the 100M default targets a real accelerator)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    # default: ~100M params, llama3-family, reduced
    cfg = ModelConfig(
        name="llama3-100m", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=8, n_kv_heads=4,
        head_dim=args.d_model // 8, d_ff=4 * args.d_model, vocab=32768,
        tie_embeddings=True)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    rules = MeshRules()
    opt = OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                          schedule="wsd")
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    state = adamw_init(params, opt)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n/1e6:.1f}M params")

    start = 0
    last = latest_step(args.ckpt_dir)
    if last is not None:
        print(f"resuming from checkpoint step {last}")
        restored = load_checkpoint(args.ckpt_dir, last,
                                   {"params": params, "state": state})
        params = jax.tree.map(jnp.asarray, restored["params"])
        state = jax.tree.map(jnp.asarray, restored["state"])
        start = last

    step = jax.jit(make_train_step(cfg, opt, mesh, rules,
                                   TrainConfig(remat="none")))
    it = make_batches(data, start_step=start)
    t0 = time.time()
    with mesh:
        for s in range(start, args.steps):
            b = next(it)
            params, state, m = step(
                params, state, {k: jnp.asarray(v) for k, v in b.items()})
            if (s + 1) % 20 == 0:
                print(f"step {s+1:4d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.3f} "
                      f"({(s + 1 - start) / (time.time() - t0):.2f} it/s)",
                      flush=True)
            if (s + 1) % args.ckpt_every == 0:
                mgr.save_async(s + 1, {"params": params, "state": state})
    mgr.wait()
    print(f"done; final loss {float(m['loss']):.4f}; "
          f"checkpoints at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
