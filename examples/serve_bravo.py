"""Serve a small model with batched requests under concurrent weight
hot-swap, comparing the engine's model-epoch lock implementations —
the paper's technique as a first-class serving feature.

    PYTHONPATH=src python examples/serve_bravo.py [--locks bravo-ba,ba]
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
from jax.sharding import Mesh

from repro import configs
from repro.dist.sharding import MeshRules
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def run_once(lock_name: str, n_requests: int = 8) -> None:
    cfg = configs.get_smoke("llama3.2-1b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    eng = ServingEngine(cfg, params, mesh=mesh, rules=MeshRules(),
                        lock_name=lock_name, handlers=1, max_seq=24,
                        slots_per_handler=2)
    # background writers: weight hot-swap + page compaction
    eng.start(swap_period_s=0.25, compact_period_s=0.4)
    t0 = time.time()
    rng = np.random.default_rng(0)
    # fixed prompt length -> one jitted (B, S) shape per batch size
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab, size=6).astype(
                        np.int32),
                    max_new=6) for i in range(n_requests)]
    for r in reqs:
        eng.submit(r)
    for r in reqs:
        assert r.done.wait(timeout=900), "request timed out"
    dt = time.time() - t0
    eng.stop()
    st = eng.lock_stats()
    engs = st["engine"]
    line = (f"{lock_name:16s} {engs['tokens_out']/dt:8.1f} tok/s  "
            f"decode_steps={engs['decode_steps']} "
            f"swaps={engs['weight_swaps']}")
    if "model" in st:
        ms = st["model"]
        tot = ms["fast_acquires"] + ms["slow_acquires"]
        line += (f"  fast-path={ms['fast_acquires']}/{tot} "
                 f"({100*ms['fast_acquires']/max(tot,1):.1f}%) "
                 f"revocations={ms['revocations']}")
    print(line, flush=True)
    print("  sample completion:", reqs[0].out.tolist())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--locks", default="bravo-ba,ba,bravo-pthread")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    for lock in args.locks.split(","):
        run_once(lock.strip(), args.requests)


if __name__ == "__main__":
    main()
